"""Serving layer: batcher packing, LRU cache semantics, engine parity."""
import numpy as np
import pytest

from repro.core.oracle import bfs_levels
from repro.graphs.rmat import pick_sources, rmat_graph
from repro.serve import BFSServeEngine, LRUCache, QueryBatcher, pack_sources


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(10, seed=7)


@pytest.fixture(scope="module")
def engine(graph):
    eng = BFSServeEngine(graph, th=32, p_rank=2, p_gpu=2, cache_capacity=64)
    eng.warmup()
    return eng


# ---------------------------------------------------------------- batcher
def test_pack_sources_splits_and_pads_nothing():
    batches = pack_sources(np.arange(70), width=32)
    assert [len(b) for b in batches] == [32, 32, 6]
    np.testing.assert_array_equal(np.concatenate(batches), np.arange(70))
    assert pack_sources([], width=32) == []


def test_batcher_fifo_and_drain():
    b = QueryBatcher(width=4)
    tickets = [b.submit(s) for s in (10, 11, 12, 13, 14)]
    assert tickets == [0, 1, 2, 3, 4] and b.pending == 5
    t1, s1 = b.next_batch()
    assert t1 == [0, 1, 2, 3] and list(s1) == [10, 11, 12, 13]
    got = list(b.drain())
    assert len(got) == 1 and list(got[0][1]) == [14]
    assert b.pending == 0


# ------------------------------------------------------------------ cache
def test_lru_eviction_order():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1        # refreshes "a"
    c.put("c", 3)                 # evicts "b" (least recent)
    assert "a" in c and "c" in c and "b" not in c
    assert c.get("b") is None
    assert c.hits == 1 and c.misses == 1


def test_lru_capacity_zero_disables():
    c = LRUCache(0)
    c.put("a", 1)
    assert len(c) == 0 and c.get("a") is None


# ----------------------------------------------------------------- engine
def test_engine_levels_match_oracle(graph, engine):
    sources = pick_sources(graph, 5, seed=2)
    levels = engine.query(sources)
    assert levels.shape == (5, graph.n)
    for s, lev in zip(sources, levels):
        np.testing.assert_array_equal(lev, bfs_levels(graph, int(s)))


def test_engine_multi_batch_and_cache(graph, engine):
    """> W unique sources span batches; a repeat call is served from cache."""
    start_batches = engine.stats.batches
    sources = pick_sources(graph, 40, seed=3)
    levels = engine.query(sources)
    assert engine.stats.batches == start_batches + 2      # 32 + 8 lanes
    for s, lev in zip(sources[::7], levels[::7]):
        np.testing.assert_array_equal(lev, bfs_levels(graph, int(s)))

    hits0 = engine.stats.cache_hits
    again = engine.query(sources[:10])
    assert engine.stats.batches == start_batches + 2      # no new traversal
    assert engine.stats.cache_hits == hits0 + 10
    np.testing.assert_array_equal(again, levels[:10])


def test_engine_duplicates_share_a_lane(graph, engine):
    """Duplicate sources in one request only occupy one lane."""
    lanes0 = engine.stats.lanes_used
    src = int(pick_sources(graph, 1, seed=11)[0])
    engine.cache.clear()
    levels = engine.query([src, src, src])
    assert engine.stats.lanes_used == lanes0 + 1
    np.testing.assert_array_equal(levels[0], levels[2])


def test_engine_delegate_source(graph, engine):
    """A replicated (delegate) vertex is a valid query source."""
    dvid = int(np.asarray(engine.pg.delegate_vids).reshape(-1)[0])
    lev = engine.query_one(dvid)
    np.testing.assert_array_equal(lev, bfs_levels(graph, dvid))
