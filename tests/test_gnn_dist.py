"""Distributed (degree-separated) GNN == local single-device reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bfs as B, engine as E
from repro.core.partition import partition_graph
from repro.graphs.synthetic import cora_like
from repro.models import equivariant as EQ, gnn as G
from repro.models.common import materialize
from repro.models.gnn import GraphBatch
from repro.train import gnn_batches as GB, gnn_dist as GD


@pytest.fixture(scope="module")
def setup():
    g, feats, labels, mask = cora_like(n=96, avg_deg=4, d_feat=12, seed=3)
    pg = partition_graph(g, th=10, p_rank=2, p_gpu=2)
    pgv = B.device_view(pg)
    plan = E.build_exchange_plan(pg)
    return g, feats, labels, mask, pg, pgv, plan


def vmapped(fn, n_tree_args):
    """vmap a per-partition fn over stacked args with axis_name 'p'."""
    return jax.jit(jax.vmap(fn, axis_name="p", in_axes=(None,) + (0,) * n_tree_args))


def test_fetch_nn_dst_correct(setup):
    g, feats, labels, mask, pg, pgv, plan = setup
    x_n, _ = E.scatter_features(pg, feats)
    fetch = vmapped(lambda params, pgl, pl, xn: E.fetch_nn_dst(pgl, pl, xn, "p"), 3)
    got = fetch(None, pgv, plan, jnp.asarray(x_n))
    # reference: per-partition nn edges' global dst features
    from repro.core.types import PartitionLayout
    layout = PartitionLayout(pg.n, pg.p_rank, pg.p_gpu)
    cols = np.asarray(pg.nn.cols)
    owners = np.asarray(pg.nn_owner)
    for k in range(pg.p):
        mk = int(np.asarray(pg.nn.m)[k])
        dst_global = layout.global_of(owners[k, :mk], cols[k, :mk])
        want = feats[dst_global]
        np.testing.assert_allclose(np.asarray(got)[k, :mk], want, rtol=1e-5, atol=1e-6)


def test_dist_gcn_matches_local(setup):
    g, feats, labels, mask, pg, pgv, plan = setup
    cfg = G.GCNConfig(n_layers=2, d_in=12, d_hidden=8, n_classes=7)
    params = materialize(G.gcn_param_specs(cfg), 0)
    w = E.build_edge_weights(pg, g.out_degrees(), "sym")
    batch = GB.gcn_batch(pg, feats, labels, mask)
    batch = jax.tree.map(jnp.asarray, batch)

    fwd = vmapped(lambda prm, pgl, pl, wl, bt: GD.dist_gcn_forward(
        cfg, prm, pgl, pl, wl, bt["x_n"], bt["x_d"], "p"), 4)
    ln, ld = fwd(params, pgv, plan, w, batch)
    # assemble global logits and compare to local model
    out = E.gather_features(pg, np.asarray(ln), np.asarray(ld)[0])
    gb = GraphBatch(nodes=jnp.asarray(feats), senders=jnp.asarray(g.src, jnp.int32),
                    receivers=jnp.asarray(g.dst, jnp.int32))
    want = np.asarray(G.gcn_forward(cfg, params, gb))
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-4)

    # loss parity
    lossf = vmapped(lambda prm, pgl, pl, wl, bt: GD.dist_gcn_loss(
        cfg, prm, pgl, pl, wl, bt, "p"), 4)
    got_loss = float(lossf(params, pgv, plan, w, batch)[0])
    want_loss = float(G.gcn_loss(cfg, params, gb, jnp.asarray(labels), jnp.asarray(mask)))
    assert abs(got_loss - want_loss) / want_loss < 1e-3


def test_dist_mgn_matches_local(setup):
    g, feats, labels, mask, pg, pgv, plan = setup
    rng = np.random.default_rng(0)
    cfg = G.MGNConfig(n_layers=2, d_hidden=8, mlp_layers=2, d_node_in=12,
                      d_edge_in=4, d_out=3)
    params = materialize(G.mgn_param_specs(cfg), 1)
    edge_feats = rng.normal(size=(g.m, 4)).astype(np.float32)
    targets = rng.normal(size=(g.n, 3)).astype(np.float32)
    batch = jax.tree.map(jnp.asarray, GB.mgn_batch(pg, feats, edge_feats, targets))

    fwd = vmapped(lambda prm, pgl, pl, bt: GD.dist_mgn_forward(cfg, prm, pgl, pl, bt, "p"), 3)
    on, od = fwd(params, pgv, plan, batch)
    out = E.gather_features(pg, np.asarray(on), np.asarray(od)[0])

    gb = GraphBatch(nodes=jnp.asarray(feats), senders=jnp.asarray(g.src, jnp.int32),
                    receivers=jnp.asarray(g.dst, jnp.int32),
                    edge_feats=jnp.asarray(edge_feats))
    want = np.asarray(G.mgn_forward(cfg, params, gb))
    np.testing.assert_allclose(out, want, rtol=5e-3, atol=5e-4)


def test_dist_mace_matches_local(setup):
    g, feats, labels, mask, pg, pgv, plan = setup
    rng = np.random.default_rng(1)
    cfg = EQ.MACEConfig(n_layers=2, d_hidden=4, n_rbf=4, n_species=5)
    params = materialize(EQ.mace_param_specs(cfg), 2)
    pos = rng.normal(size=(g.n, 3)).astype(np.float32) * 2
    spec = rng.integers(0, 5, g.n).astype(np.int32)
    batch = jax.tree.map(jnp.asarray, GB.mace_batch(pg, pos, spec, 0.0))

    lossf = vmapped(lambda prm, pgl, pl, bt: GD.dist_mace_loss(cfg, prm, pgl, pl, bt, "p"), 3)
    got = float(jnp.sqrt(lossf(params, pgv, plan, batch)[0]))  # |E_total|
    want = float(np.abs(np.asarray(
        EQ.mace_forward(cfg, params, jnp.asarray(pos), jnp.asarray(spec),
                        jnp.asarray(g.src, jnp.int32), jnp.asarray(g.dst, jnp.int32))).sum()))
    assert abs(got - want) / max(want, 1e-6) < 5e-3, (got, want)


def test_dist_grads_match_local(setup):
    """d(dist loss)/d(params) == d(local loss)/d(params): the collective
    transposes deliver the full global gradient with no extra psum."""
    g, feats, labels, mask, pg, pgv, plan = setup
    cfg = G.GCNConfig(n_layers=2, d_in=12, d_hidden=8, n_classes=7)
    params = materialize(G.gcn_param_specs(cfg), 0)
    w = E.build_edge_weights(pg, g.out_degrees(), "sym")
    batch = jax.tree.map(jnp.asarray, GB.gcn_batch(pg, feats, labels, mask))
    loss_local = lambda prm, pgl, pl, wl, bt: GD.dist_gcn_loss(cfg, prm, pgl, pl, wl, bt, "p")
    gfn = lambda *a: jax.lax.pmean(jax.grad(loss_local)(*a), "p")
    gv = jax.jit(jax.vmap(gfn, axis_name="p", in_axes=(None, 0, 0, 0, 0)))
    gdist = gv(params, pgv, plan, w, batch)
    gb = GraphBatch(nodes=jnp.asarray(feats), senders=jnp.asarray(g.src, jnp.int32),
                    receivers=jnp.asarray(g.dst, jnp.int32))
    gref = jax.grad(lambda p: G.gcn_loss(cfg, p, gb, jnp.asarray(labels), jnp.asarray(mask)))(params)
    for k in gref:
        for lane in range(pg.p):
            np.testing.assert_allclose(np.asarray(gdist[k][lane]), np.asarray(gref[k]),
                                       rtol=2e-3, atol=2e-5)


def test_dist_train_step_tracks_local(setup):
    """Distributed SGD trajectory == single-device SGD trajectory."""
    g, feats, labels, mask, pg, pgv, plan = setup
    from repro.train.optim import SGD
    cfg = G.GCNConfig(n_layers=2, d_in=12, d_hidden=8, n_classes=7)
    params = materialize(G.gcn_param_specs(cfg), 0)
    w = E.build_edge_weights(pg, g.out_degrees(), "sym")
    batch = jax.tree.map(jnp.asarray, GB.gcn_batch(pg, feats, labels, mask))
    opt = SGD(lr=0.5, momentum=0.9)

    loss_local = lambda prm, pgl, pl, wl, bt: GD.dist_gcn_loss(cfg, prm, pgl, pl, wl, bt, "p")
    step = GD.make_dist_train_step(loss_local, opt, "p")
    stepv = jax.jit(jax.vmap(step, axis_name="p", in_axes=(None, None, 0, 0, 0, 0),
                             out_axes=(None, None, 0)))
    gb = GraphBatch(nodes=jnp.asarray(feats), senders=jnp.asarray(g.src, jnp.int32),
                    receivers=jnp.asarray(g.dst, jnp.int32))
    p_d, st_d = params, opt.init(params)
    p_l, st_l = params, opt.init(params)
    for _ in range(5):
        p_d, st_d, loss_d = stepv(p_d, st_d, pgv, plan, w, batch)
        g_l = jax.grad(lambda p: G.gcn_loss(cfg, p, gb, jnp.asarray(labels), jnp.asarray(mask)))(p_l)
        p_l, st_l = opt.update(g_l, st_l, p_l)
    for k in p_l:
        np.testing.assert_allclose(np.asarray(p_d[k]), np.asarray(p_l[k]), rtol=5e-3, atol=5e-4)


def test_dist_mace_pos_only_fetch_parity(setup):
    """SPerf optimization: positions-only nn fetch is bit-equivalent (the
    messages never read remote irreps)."""
    g, feats, labels, mask, pg, pgv, plan = setup
    import dataclasses
    rng = np.random.default_rng(2)
    base = EQ.MACEConfig(n_layers=2, d_hidden=4, n_rbf=4, n_species=5)
    opt = dataclasses.replace(base, dist_fetch_pos_only=True)
    params = materialize(EQ.mace_param_specs(base), 7)
    pos = rng.normal(size=(g.n, 3)).astype(np.float32) * 2
    spec = rng.integers(0, 5, g.n).astype(np.int32)
    batch = jax.tree.map(jnp.asarray, GB.mace_batch(pg, pos, spec, 0.0))
    run2 = lambda cfg: float(vmapped(
        lambda prm, pgl, pl, bt: GD.dist_mace_loss(cfg, prm, pgl, pl, bt, "p"), 3
    )(params, pgv, plan, batch)[0])
    a, b = run2(base), run2(opt)
    assert abs(a - b) / max(abs(a), 1e-9) < 1e-5, (a, b)
