"""Training substrate: optimizers, accumulation, checkpointing, fault driver."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as C, fault as F, optim as O
from repro.train.trainer import make_train_step


def quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {"rmse": jnp.sqrt(loss)}


def make_problem(seed=0, n=256):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)
    x = rng.normal(size=(n, 4)).astype(np.float32)
    y = x @ w_true + 0.5
    params = {"w": jnp.zeros((4, 1)), "b": jnp.zeros((1,))}
    return params, {"x": jnp.asarray(x), "y": jnp.asarray(y)}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgd"])
def test_optimizers_converge(opt_name):
    params, batch = make_problem()
    opt = O.get_optimizer(opt_name, lr=0.05)
    step = jax.jit(make_train_step(quad_loss, opt))
    state = opt.init(params)
    for _ in range(300):
        params, state, metrics = step(params, state, batch)
    assert float(metrics["loss"]) < 1e-2, (opt_name, float(metrics["loss"]))


def test_grad_accumulation_matches_full_batch():
    params, batch = make_problem()
    opt = O.AdamW(lr=0.1, clip_norm=0.0)
    s1 = jax.jit(make_train_step(quad_loss, opt, grad_accum=1))
    s4 = jax.jit(make_train_step(quad_loss, opt, grad_accum=4))
    p1, st1, _ = s1(params, opt.init(params), batch)
    p4, st4, _ = s4(params, opt.init(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((64, 32))}
    opt = O.Adafactor(lr=1e-2)
    st = opt.init(params)
    sizes = sum(x.size for x in jax.tree.leaves(st["stats"]))
    assert sizes == 64 + 32  # not 64*32


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = O.clip_by_global_norm(tree, 1.0)
    assert float(O.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100.0


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "s": {"v": jnp.ones((2,))}}
    d = str(tmp_path / "ck")
    C.save(d, 10, tree)
    C.save(d, 20, jax.tree.map(lambda x: x * 2, tree))
    assert C.latest_step(d) == 20
    step, restored = C.restore(d, tree)
    assert step == 20
    np.testing.assert_allclose(np.asarray(restored["w"]), np.arange(12.0).reshape(3, 4) * 2)
    # a partially-written (manifest-less) dir is ignored
    os.makedirs(os.path.join(d, "step_00000030"))
    assert C.latest_step(d) == 20
    # corruption detection
    import glob
    f = glob.glob(os.path.join(d, "step_00000020", "*.npz"))[0]
    with open(f, "r+b") as fh:
        fh.seek(10)
        fh.write(b"\xde\xad")
    with pytest.raises(IOError):
        C.restore(d, tree, step=20)


def test_checkpoint_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.zeros((2,))}
    for s in range(5):
        C.save(d, s, tree, keep=2)
    assert sorted(C.all_steps(d)) == [3, 4]


def test_resilient_driver_restarts_and_finishes(tmp_path):
    """Inject a crash at step 7; driver must restore and complete all steps
    with bit-identical data replay."""
    d = str(tmp_path / "ck")
    params, batch = make_problem()
    opt = O.SGD(lr=0.05)
    tstep = jax.jit(make_train_step(quad_loss, opt))
    crashed = {"done": False}

    def init_state():
        return 0, {"params": params, "opt": opt.init(params)}

    def step_fn(step, state):
        p, o, m = tstep(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    def fault_hook(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    rep = F.run_resilient(
        ckpt_dir=d, init_state=init_state, step_fn=step_fn, total_steps=12,
        ckpt_every=5, fault_hook=fault_hook,
    )
    assert rep.final_step == 12
    assert rep.restarts == 1
    # restart replayed steps 5..7 (crash after ckpt at 5)
    assert rep.steps_run == 12 + 2


def test_straggler_monitor():
    mon = F.StragglerMonitor(window=16, threshold=2.0)
    flagged = [mon.observe(0.1) for _ in range(10)]
    assert not any(flagged)
    assert mon.observe(1.0) is True
