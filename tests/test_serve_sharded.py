"""Sharded serving parity: the engine on a 1-device mesh (degenerates to
the emulated path) and on a real multi-device mesh (CPU devices forced via
XLA_FLAGS) must produce oracle-identical levels for every lane, refilled
lanes included.

The multi-device variants run in-process when the interpreter already has
>= 4 host devices (the CI job forcing
``--xla_force_host_platform_device_count=4`` exercises them on every push)
and via a subprocess with XLA_FLAGS forced otherwise (``-m slow``).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import msbfs as M
from repro.core.oracle import (bfs_levels, bfs_levels_limited, reachable_mask,
                               target_depths)
from repro.graphs.rmat import pick_sources, rmat_graph
from repro.graphs.synthetic import with_tails
from repro.launch.mesh import make_test_mesh
from repro.serve import BFSServeEngine, Query, QueryKind

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 host devices (run under the multi-device CI job)")


def _stream_and_graph():
    core = rmat_graph(8, seed=11)
    g, tips = with_tails(core, n_tails=2, length=16, seed=2)
    stream = np.concatenate([[tips[0]], pick_sources(core, 7, seed=3), [tips[1]]])
    return g, stream


def _check_engine(eng, g, stream):
    levels = eng.query(stream)
    for s, lev in zip(stream, levels):
        np.testing.assert_array_equal(lev, bfs_levels(g, int(s)))


def _mixed_queries(eng, g, stream):
    """All four kinds (delegate source included) over the stream sources."""
    dvid = int(np.asarray(eng.pg.delegate_vids).reshape(-1)[0])
    srcs = [int(s) for s in stream]
    tg = tuple(srcs[:2])
    kinds = [lambda s: Query(s),
             lambda s: Query(s, QueryKind.REACHABILITY),
             lambda s: Query(s, QueryKind.DISTANCE_LIMITED, max_depth=2),
             lambda s: Query(s, QueryKind.MULTI_TARGET, targets=tg)]
    return [kinds[i % 4](s) for i, s in enumerate(srcs)] + \
        [Query(dvid, QueryKind.REACHABILITY)]


def _check_mixed(eng, g, stream):
    qs = _mixed_queries(eng, g, stream)
    for q, a in zip(qs, eng.submit_many(qs)):
        if q.kind is QueryKind.LEVELS:
            np.testing.assert_array_equal(a, bfs_levels(g, q.source))
        elif q.kind is QueryKind.REACHABILITY:
            np.testing.assert_array_equal(a, reachable_mask(g, q.source))
        elif q.kind is QueryKind.DISTANCE_LIMITED:
            np.testing.assert_array_equal(
                a, bfs_levels_limited(g, q.source, q.max_depth))
        else:
            assert a == target_depths(g, q.source, q.targets)


def test_one_device_mesh_degenerates_to_emulated():
    """mesh= spanning one device keeps the vmap path (sharded=False) and
    stays oracle-exact, refill included."""
    g, stream = _stream_and_graph()
    mesh = make_test_mesh((1,), ("p",))
    cfg = M.MSBFSConfig(n_queries=4, max_iters=80)
    eng = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                         cache_capacity=0, mesh=mesh, refill=True)
    assert not eng.sharded
    _check_engine(eng, g, stream)
    assert eng.stats.refills >= len(stream) - 4


def test_mesh_partition_mismatch_raises():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices to build a multi-device mesh")
    mesh = make_test_mesh((2,), ("p",))
    with pytest.raises(ValueError):
        BFSServeEngine(rmat_graph(7, seed=1), th=32, p_rank=1, p_gpu=1,
                       mesh=mesh)


@needs4
def test_sharded_engine_parity_multidevice():
    """shard_map engine (one partition per device): batch mode parity."""
    g, stream = _stream_and_graph()
    mesh = make_test_mesh((2, 2), ("data", "model"))
    cfg = M.MSBFSConfig(n_queries=4, max_iters=80)
    eng = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                         cache_capacity=0, mesh=mesh, refill=False)
    assert eng.sharded
    _check_engine(eng, g, stream)


@needs4
def test_sharded_refill_parity_multidevice():
    """shard_map engine with mid-flight refill: every lane, every refill
    generation, oracle-exact."""
    g, stream = _stream_and_graph()
    mesh = make_test_mesh((2, 2), ("data", "model"))
    cfg = M.MSBFSConfig(n_queries=4, max_iters=80)
    eng = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                         cache_capacity=0, mesh=mesh, refill=True)
    assert eng.sharded
    _check_engine(eng, g, stream)
    assert eng.stats.refills >= len(stream) - 4


@needs4
@pytest.mark.parametrize("refill", [False, True])
def test_sharded_mixed_kind_parity_multidevice(refill):
    """All four typed query kinds mixed in one stream (one refill batch
    when refill=True) on a real 4-device shard_map mesh: oracle-exact."""
    g, stream = _stream_and_graph()
    mesh = make_test_mesh((2, 2), ("data", "model"))
    cfg = M.MSBFSConfig(n_queries=4, max_iters=80)
    eng = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                         cache_capacity=0, mesh=mesh, refill=refill)
    assert eng.sharded
    _check_mixed(eng, g, stream)
    assert eng.stats.early_stops > 0


@needs4
def test_sharded_reachability_fast_path_multidevice():
    """The levels-free reachability variant compiles and stays oracle-exact
    under shard_map on 4 devices."""
    g, stream = _stream_and_graph()
    mesh = make_test_mesh((2, 2), ("data", "model"))
    cfg = M.MSBFSConfig(n_queries=4, max_iters=80)
    eng = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                         cache_capacity=0, mesh=mesh, refill=True,
                         reuse_components=False)
    assert eng.sharded
    qs = [Query(int(s), QueryKind.REACHABILITY) for s in stream]
    for q, a in zip(qs, eng.submit_many(qs)):
        np.testing.assert_array_equal(a, reachable_mask(g, q.source))
    assert eng.stats.reach_fast_batches >= 1


SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
sys.path.insert(0, "tests")
import numpy as np
import test_serve_sharded as T

g, stream = T._stream_and_graph()
from repro.core import msbfs as M
from repro.launch.mesh import make_test_mesh
from repro.serve import BFSServeEngine

mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = M.MSBFSConfig(n_queries=4, max_iters=80)
eng = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                     cache_capacity=0, mesh=mesh, refill=True)
assert eng.sharded
T._check_engine(eng, g, stream)
assert eng.stats.refills >= len(stream) - 4
T._check_mixed(eng, g, stream)
assert eng.stats.early_stops > 0
print("sharded refill parity OK")
"""


@pytest.mark.slow
def test_sharded_refill_parity_subprocess():
    """Same parity check with XLA_FLAGS forced in a fresh interpreter (for
    1-device hosts; the multi-device CI job runs the in-process variants)."""
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "sharded refill parity OK" in r.stdout
