"""Per-architecture smoke tests: reduced config, one real train/serve step on
CPU (1x1 mesh), output shapes + no NaNs. The FULL configs are exercised only
via the dry-run (ShapeDtypeStructs, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.launch.cells import build_cell
from repro.launch.mesh import make_test_mesh


def materialize_args(args, seed=0):
    """SDS pytree -> small random/zero arrays (graph structures get zeros,
    which encode a valid empty graph)."""
    rng = np.random.default_rng(seed)
    def one(s):
        if not hasattr(s, "dtype"):
            return s
        if np.issubdtype(s.dtype, np.floating) or s.dtype == jnp.bfloat16:
            # non-negative: optimizer second-moment states must be >= 0
            return jnp.asarray(np.abs(rng.normal(size=s.shape)) * 0.02, s.dtype)
        if s.dtype == np.bool_:
            return jnp.zeros(s.shape, np.bool_)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(one, args)


MESH = None


def mesh1():
    global MESH
    if MESH is None:
        MESH = make_test_mesh((1, 1), ("data", "model"))
    return MESH


PRIMARY = {
    "lm": "train_4k", "gnn": "molecule", "recsys": "train_batch", "bfs": "rmat_s30",
}

LM_ARCHS = ["gemma3-1b", "granite-34b", "qwen2.5-14b", "kimi-k2-1t-a32b", "qwen2-moe-a2.7b"]


@pytest.mark.parametrize("arch", all_archs())
def test_arch_primary_smoke(arch):
    spec = get_arch(arch)
    shape = PRIMARY[spec.family]
    fn, args = build_cell(arch, shape, mesh1(), smoke=True)
    out = fn(*materialize_args(args))
    leaves = [x for x in jax.tree.leaves(out) if hasattr(x, "dtype")]
    assert leaves, "step produced no outputs"
    for x in leaves:
        if np.issubdtype(np.dtype(x.dtype), np.floating):
            assert bool(jnp.isfinite(x).all()), arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_smoke(arch):
    fn, args = build_cell(arch, "decode_32k", mesh1(), smoke=True)
    logits, cache = fn(*materialize_args(args))
    spec = get_arch(arch)
    assert logits.shape == (4, spec.smoke.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_smoke(arch):
    fn, args = build_cell(arch, "prefill_32k", mesh1(), smoke=True)
    logits, cache = fn(*materialize_args(args))
    assert logits.shape[0] == 4 and bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", [
    "gcn-cora", "meshgraphnet", "graphcast",
    # the mace variant is the suite's single most expensive test (~13s) and
    # its dist-engine coverage is duplicated by
    # test_gnn_dist.py::test_dist_mace_matches_local, so it rides -m slow
    pytest.param("mace", marks=pytest.mark.slow),
])
def test_gnn_dist_full_smoke(arch):
    """Degree-separated engine cell on the 1x1 mesh (p=1 partition)."""
    fn, args = build_cell(arch, "full_graph_sm", mesh1(), smoke=True)
    params, opt, loss = fn(*materialize_args(args))
    assert bool(jnp.isfinite(loss).all()), arch


def test_recsys_serve_and_retrieval_smoke():
    fn, args = build_cell("xdeepfm", "serve_p99", mesh1(), smoke=True)
    logits = fn(*materialize_args(args))
    assert logits.shape == (8,) and bool(jnp.isfinite(logits).all())
    fn, args = build_cell("xdeepfm", "retrieval_cand", mesh1(), smoke=True)
    scores, idx = fn(*materialize_args(args))
    assert scores.shape == (8, 100)


def test_bfs_cell_smoke():
    fn, args = build_cell("bfs-rmat", "rmat_s30", mesh1(), smoke=True)
    out = fn(*materialize_args(args))
    assert int(np.asarray(out.it)[0]) <= 2  # empty graph terminates at once


def test_skip_annotations():
    """long_500k is skipped exactly for the pure full-attention archs."""
    for arch in ("granite-34b", "qwen2.5-14b", "kimi-k2-1t-a32b", "qwen2-moe-a2.7b"):
        assert "long_500k" in get_arch(arch).skip
    assert "long_500k" not in get_arch("gemma3-1b").skip  # hybrid: runs


def test_cell_enumeration():
    from repro.launch.cells import all_cells
    cells = [c for c in all_cells(include_skipped=True) if "-opt" not in c[0]]
    assert len(cells) == 5 * 4 + 4 * 4 + 4 + 2   # 40 assigned + 2 bfs shapes
    runnable = [c for c in cells if c[2] is None]
    assert len(runnable) == len(cells) - 4       # 4 long_500k skips
