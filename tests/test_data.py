"""Data pipelines: determinism across restarts (the fault-tolerance
contract), sharding discipline, and shape stability."""
import numpy as np

from repro.data.recsys_data import ClickStream
from repro.data.tokens import TokenStream


def test_token_stream_deterministic_across_restarts():
    a = TokenStream(vocab=1000, seq_len=32, global_batch=8, seed=7)
    b = TokenStream(vocab=1000, seq_len=32, global_batch=8, seed=7)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(a.batch(step)["tokens"], b.batch(step)["tokens"])


def test_token_stream_shards_disjoint():
    shards = [TokenStream(1000, 16, 8, seed=3, shard=i, num_shards=4) for i in range(4)]
    batches = [s.batch(0)["tokens"] for s in shards]
    assert all(b.shape == (2, 16) for b in batches)
    # different shards see different data
    assert not np.array_equal(batches[0], batches[1])


def test_token_stream_steps_differ():
    s = TokenStream(1000, 16, 4, seed=0)
    assert not np.array_equal(s.batch(0)["tokens"], s.batch(1)["tokens"])


def test_token_labels_are_shifted_tokens():
    s = TokenStream(1000, 16, 4, seed=0)
    b = s.batch(0)
    assert b["tokens"].shape == b["labels"].shape


def test_clickstream_stable_hot_cold_split():
    """The delegate (hot/cold) split is a property of the table, not the
    batch: two streams with the same seed agree on every row's class."""
    a = ClickStream(n_fields=6, total_vocab=1 << 12, seed=5)
    b = ClickStream(n_fields=6, total_vocab=1 << 12, seed=5)
    np.testing.assert_array_equal(a.hot_cold.hot_of, b.hot_cold.hot_of)
    assert a.hot_cold.n_hot + a.hot_cold.n_cold == int(a.vocab_sizes.sum())


def test_clickstream_indices_in_range():
    cs = ClickStream(n_fields=6, total_vocab=1 << 12, seed=1)
    batch = cs.batch(3, 128)
    hot, cold = batch["hot_idx"], batch["cold_idx"]
    assert hot.max() < cs.hot_cold.n_hot
    assert cold.max() < cs.hot_cold.n_cold
    assert ((hot >= 0) ^ (cold >= 0)).all()
