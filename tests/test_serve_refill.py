"""Mid-flight lane refill: scheduler bookkeeping, engine parity on a
skewed-depth stream, LRU + ServeStats accounting under refill."""
import numpy as np
import pytest

from repro.core import msbfs as M
from repro.core.oracle import bfs_levels
from repro.graphs.rmat import pick_sources, rmat_graph
from repro.graphs.synthetic import with_tails
from repro.serve import BFSServeEngine, LaneScheduler, LRUCache


@pytest.fixture(scope="module")
def tailed():
    """Small RMAT core with two long tails: a skewed depth distribution."""
    core = rmat_graph(8, seed=11)
    g, tips = with_tails(core, n_tails=2, length=24, seed=2)
    return core, g, tips


def make_engine(g, *, w=4, cache=32, **kw):
    cfg = M.MSBFSConfig(n_queries=w, max_iters=96)
    return BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                          cache_capacity=cache, refill=True, **kw)


# ------------------------------------------------------------ LaneScheduler
def test_lane_scheduler_generations():
    s = LaneScheduler(2, pending=[10, 11, 12])
    a = s.fill_idle()
    assert [(x.lane, x.source, x.generation) for x in a] == [(0, 10, 1), (1, 11, 1)]
    assert s.n_busy == 2 and s.n_pending == 1
    assert s.fill_idle() == []                       # no idle lane
    assert s.retire(0) == (10, 1)
    b = s.fill_idle()
    assert [(x.lane, x.source, x.generation) for x in b] == [(0, 12, 2)]
    assert s.retire(0) == (12, 2)                    # generation advanced
    assert s.retire(1) == (11, 1)
    assert s.n_busy == 0 and s.n_pending == 0
    with pytest.raises(ValueError):
        s.retire(1)                                  # idle lane
    s.submit(13)
    assert [x.source for x in s.fill_idle()] == [13]


def test_lane_scheduler_rejects_bad_width():
    with pytest.raises(ValueError):
        LaneScheduler(0)


# ------------------------------------------------------- refill engine parity
def test_refill_parity_skewed_stream(tailed):
    """Deep tail queries and shallow core queries interleaved through W=4
    lanes: every answer (refilled lanes included) matches the oracle."""
    core, g, tips = tailed
    shallow = pick_sources(core, 10, seed=3)
    stream = np.concatenate([[tips[0]], shallow[:5], [tips[1]], shallow[5:]])
    eng = make_engine(g)
    levels = eng.query(stream)
    for s, lev in zip(stream, levels):
        np.testing.assert_array_equal(lev, bfs_levels(g, int(s)))
    # 12 queries through 4 lanes: at least 8 mid-flight reseeds
    assert eng.stats.refills >= len(stream) - eng.cfg.n_queries
    assert eng.stats.sweeps > 0
    assert 0.0 < eng.stats.lane_utilization <= 1.0


def test_refill_delegate_and_repeat_sources(tailed):
    _, g, _ = tailed
    eng = make_engine(g)
    dvid = int(np.asarray(eng.pg.delegate_vids).reshape(-1)[0])
    out = eng.query([dvid, 3, dvid])                 # duplicate + delegate
    np.testing.assert_array_equal(out[0], bfs_levels(g, dvid))
    np.testing.assert_array_equal(out[0], out[2])
    assert eng.stats.lanes_used == 2                 # dedup: one lane each


def test_refill_matches_batch_engine(tailed):
    """Refill and batch-at-a-time are answer-identical on the same stream."""
    core, g, tips = tailed
    stream = np.concatenate([pick_sources(core, 6, seed=9), tips])
    cfg = M.MSBFSConfig(n_queries=4, max_iters=96)
    eng_b = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                           cache_capacity=0, refill=False)
    eng_r = BFSServeEngine(g, th=32, p_rank=2, p_gpu=2, cfg=cfg,
                           cache_capacity=0, refill=True)
    np.testing.assert_array_equal(eng_r.query(stream), eng_b.query(stream))


def test_refill_rejects_out_of_range(tailed):
    _, g, _ = tailed
    eng = make_engine(g)
    with pytest.raises(ValueError):
        eng.query([g.n])


def test_run_refill_dedups_duplicate_sources(tailed):
    """Direct run_refill with duplicates: one lane, one result entry (the
    generation bookkeeping must not collide on the shared source key)."""
    core, g, _ = tailed
    eng = make_engine(g, cache=0)
    s = int(pick_sources(core, 1, seed=4)[0])
    got = eng.run_refill(np.asarray([s, s, s]))
    assert list(got) == [s]
    np.testing.assert_array_equal(got[s], bfs_levels(g, s))
    assert eng.stats.lanes_used == 1


# ----------------------------------------------------------- stats accounting
def test_stats_accounting_refill_vs_batch(tailed):
    """lanes_used counts every traversed query once in both modes; padding
    follows the documented per-mode sum rules."""
    core, g, _ = tailed
    w = 4
    sources = pick_sources(core, 10, seed=5)

    eng_b = make_engine(g, w=w, cache=0)
    eng_b.refill = False
    eng_b.query(sources)
    st = eng_b.stats
    assert st.lanes_used == len(sources)
    assert st.batches == -(-len(sources) // w)
    assert st.lanes_used + st.lanes_padded == st.batches * w

    eng_r = make_engine(g, w=w, cache=0)
    eng_r.query(sources)                             # one session, k > W
    st = eng_r.stats
    assert st.lanes_used == len(sources)
    assert st.batches == 1
    assert st.lanes_used + st.lanes_padded == max(w, len(sources))
    assert st.refills == len(sources) - w
    assert st.lane_sweeps_total == st.sweeps * w
    assert 0 < st.lane_sweeps_busy <= st.lane_sweeps_total

    eng_r.query(pick_sources(core, 2, seed=8))       # second session, k < W
    st = eng_r.stats
    assert st.lanes_used == len(sources) + 2
    assert st.lanes_padded == w - 2                  # only the partial session pads


# ------------------------------------------------------------- cache + refill
def test_lru_eviction_order_is_retirement_order(tailed):
    """With capacity < misses the cache keeps the most recently *retired*
    queries; an immediate repeat query is served without new sweeps."""
    core, g, _ = tailed
    sources = pick_sources(core, 6, seed=7)
    eng = make_engine(g, w=4, cache=3)
    eng.query(sources)
    assert len(eng.cache) == 3
    assert eng.cache.evictions == 3
    cached = [k[-1] for k in eng.cache._data]        # insertion == retirement order
                                                     # (key = (graph, kind, params, source))
    sweeps0 = eng.stats.sweeps
    hits0 = eng.stats.cache_hits
    again = eng.query(cached)
    assert eng.stats.sweeps == sweeps0               # pure cache traffic
    assert eng.stats.cache_hits == hits0 + 3
    for s, lev in zip(cached, again):
        np.testing.assert_array_equal(lev, bfs_levels(g, int(s)))


def test_lru_eviction_evicts_least_recent_under_mixed_traffic():
    c = LRUCache(3)
    for k in "abc":
        c.put(k, k.upper())
    assert c.get("a") == "A"                         # refresh a
    c.put("d", "D")                                  # evicts b
    assert "b" not in c and all(k in c for k in "acd")
    c.put("e", "E")                                  # evicts c (a was refreshed)
    assert "c" not in c and all(k in c for k in "ade")
    assert c.evictions == 2
    assert c.hits == 1


def test_lru_put_refreshes_existing_key():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    c.put("a", 3)                                    # refresh + overwrite
    c.put("c", 4)                                    # evicts b, not a
    assert c.get("a") == 3 and "b" not in c and "c" in c


def test_lru_len_and_contains_agree_with_get_on_expiry():
    """``len`` and ``in`` must never report an entry ``get`` would refuse:
    expired entries are purged (and counted) by every observer."""
    t = [0.0]
    c = LRUCache(8, ttl=10.0, clock=lambda: t[0])
    c.put("a", 1)
    c.put("b", 2, ttl=None)                          # never expires
    c.put("c", 3, ttl=30.0)
    assert len(c) == 3 and "a" in c and c.expired == 0
    t[0] = 10.0                                      # a's deadline hits
    assert "a" not in c                              # purged via __contains__
    assert c.expired == 1
    assert len(c) == 2                               # and stays purged
    assert c.get("a") is None and c.misses == 1
    t[0] = 40.0                                      # c expires too
    assert len(c) == 1                               # purged via __len__
    assert c.expired == 2
    assert "b" in c and c.get("b") == 2              # ttl=None never expires
    c.put("a", 9)                                    # re-inserting is fresh
    assert len(c) == 2 and "a" in c and c.get("a") == 9


def test_lru_expired_entry_counted_once():
    t = [0.0]
    c = LRUCache(4, ttl=5.0, clock=lambda: t[0])
    c.put("k", 1)
    t[0] = 6.0
    assert "k" not in c and "k" not in c             # second probe: plain miss
    assert c.expired == 1
    assert len(c) == 0 and c.expired == 1
