"""Integration tests that need multiple XLA host devices: run in a
subprocess with XLA_FLAGS set before jax import (the main test process must
keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.core import bfs as B, engine as E
from repro.core.partition import partition_graph
from repro.core.oracle import bfs_levels
from repro.graphs.rmat import rmat_graph, pick_sources
from repro.graphs.synthetic import cora_like
from repro.launch.mesh import make_test_mesh
from repro.models import gnn as G
from repro.models.common import materialize
from repro.models.gnn import GraphBatch
from repro.train import gnn_batches as GB, gnn_dist as GD

mesh = make_test_mesh((2, 4), ("pod", "data"))
axes = ("pod", "data")
sh = lambda x: jax.device_put(x, NamedSharding(mesh, P(axes, *([None] * (np.ndim(x) - 1)))))

# ---- BFS under real shard_map matches the oracle
g = rmat_graph(11, seed=5)
pg = partition_graph(g, th=45, p_rank=2, p_gpu=4)
cfg = B.BFSConfig(max_iters=32, enable_do=True)
run = B.make_sharded_bfs(mesh, axes, cfg)
pgv_s = jax.tree.map(sh, B.device_view(pg))
src = int(pick_sources(g, 1, seed=3)[0])
out = jax.tree.map(np.asarray, run(pgv_s, jax.tree.map(sh, B.init_state(pg, src, cfg))))
assert np.array_equal(B.gather_levels(pg, out), bfs_levels(g, src)), "BFS mismatch"
assert out.nn_overflow.sum() == 0
print("BFS shard_map OK")

# ---- distributed GCN grads under shard_map == local reference
g2, feats, labels, mask = cora_like(n=96, avg_deg=4, d_feat=12, seed=3)
pg2 = partition_graph(g2, th=10, p_rank=2, p_gpu=4)
pgv2 = B.device_view(pg2)
plan = E.build_exchange_plan(pg2)
w = E.build_edge_weights(pg2, g2.out_degrees(), "sym")
batch = jax.tree.map(jnp.asarray, GB.gcn_batch(pg2, feats, labels, mask))
cfgG = G.GCNConfig(n_layers=2, d_in=12, d_hidden=8, n_classes=7)
params = materialize(G.gcn_param_specs(cfgG), 0)

def local(prm, pgl, pl, wl, bt):
    sq = lambda t: jax.tree.map(lambda x: x[0], t)
    gr = jax.grad(lambda q: GD.dist_gcn_loss(cfgG, q, sq(pgl), sq(pl), sq(wl), sq(bt), axes))(prm)
    return jax.lax.pmean(gr, axes)

in_specs = (jax.tree.map(lambda _: P(), params),
            *[jax.tree.map(lambda x: P(axes, *([None]*(x.ndim-1))), t)
              for t in (pgv2, plan, w, batch)])
gfn = jax.jit(compat.shard_map(local, mesh=mesh, in_specs=in_specs,
                               out_specs=jax.tree.map(lambda _: P(), params), check_vma=False))
gdist = gfn(params, *jax.tree.map(sh, (pgv2, plan, w, batch)))
gb = GraphBatch(nodes=jnp.asarray(feats), senders=jnp.asarray(g2.src, jnp.int32),
                receivers=jnp.asarray(g2.dst, jnp.int32))
gref = jax.grad(lambda p: G.gcn_loss(cfgG, p, gb, jnp.asarray(labels), jnp.asarray(mask)))(params)
for k in gref:
    np.testing.assert_allclose(np.asarray(gdist[k]), np.asarray(gref[k]), rtol=3e-3, atol=3e-5)
print("GCN shard_map grads OK")
"""


@pytest.mark.slow
def test_shardmap_integration():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "BFS shard_map OK" in r.stdout
    assert "GCN shard_map grads OK" in r.stdout
