"""Generalized propagation engine vs dense reference (paper Section VI-D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import bfs as B, engine as E
from repro.core.partition import partition_graph
from repro.core.types import COOGraph
from repro.graphs.rmat import rmat_graph


def dense_reference(g, X, mode):
    deg = np.maximum(g.out_degrees().astype(np.float64), 1.0)
    A = np.zeros((g.n, g.n))
    for u, v in zip(g.src, g.dst):
        w = {"sum": 1.0, "sym": 1 / np.sqrt(deg[u] * deg[v]), "mean": 1 / deg[v]}[mode]
        A[v, u] += w
    return (A @ X.astype(np.float64)).astype(np.float32)


def run_propagate(g, pg, X, mode):
    pgv = B.device_view(pg)
    plan = E.build_exchange_plan(pg)
    w = E.build_edge_weights(pg, g.out_degrees(), mode)
    x_n, x_d = E.scatter_features(pg, X)
    prop = jax.jit(
        jax.vmap(
            lambda pgl, pl, wl, xn, xd: E.propagate(pgl, pl, wl, xn, xd, "p"),
            axis_name="p", in_axes=(0, 0, 0, 0, None),
        )
    )
    out_n, out_d = prop(pgv, plan, w, jnp.asarray(x_n), jnp.asarray(x_d))
    return E.gather_features(pg, np.asarray(out_n), np.asarray(out_d)[0])


@pytest.mark.parametrize("mode", ["sum", "sym", "mean"])
@pytest.mark.parametrize("th,p_rank,p_gpu", [(16, 2, 2), (64, 1, 4), (4, 3, 1)])
def test_propagate_matches_dense(mode, th, p_rank, p_gpu):
    g = rmat_graph(8, seed=1).deduped().without_self_loops()
    pg = partition_graph(g, th=th, p_rank=p_rank, p_gpu=p_gpu)
    X = np.random.default_rng(0).normal(size=(g.n, 7)).astype(np.float32)
    out = run_propagate(g, pg, X, mode)
    ref = dense_reference(g, X, mode)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(8, 48),
    m=st.integers(8, 200),
    th=st.integers(1, 12),
    seed=st.integers(0, 1000),
)
def test_propagate_property(n, m, th, seed):
    """Linearity + exactness on random graphs: engine == dense A @ X."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    g = COOGraph(n, src, dst).without_self_loops().symmetrized().deduped()
    if g.m == 0:
        return
    pg = partition_graph(g, th=th, p_rank=2, p_gpu=1)
    X = rng.normal(size=(n, 3)).astype(np.float32)
    out = run_propagate(g, pg, X, "sum")
    ref = dense_reference(g, X, "sum")
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)
